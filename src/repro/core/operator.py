"""BridgeOperator — the reconciler (paper §5.1).

Watches BridgeJob custom resources and drives the world toward their
declared state:

  * CR created   -> create the per-job config map (populated from the spec),
                    create the controller pod (one per remote job).
  * pod dies     -> if the job is not terminal, RESTART the pod; the new pod
                    finds the remote id in the config map and resumes
                    monitoring (never resubmits).
  * CR kill flag -> write kill=true into the config map; the pod's monitor
                    loop cancels the remote job.
  * CR deleted   -> kill pod, delete config map, purge the CR (cleanup).
  * always       -> mirror config-map state into CR.status
                    (DONE/KILLED/FAILED/UNKNOWN + start/end times).

The operator is GENERIC: nothing here knows which resource manager is behind
a job — that knowledge lives in the controller-pod adapter chosen by
``spec.image`` (paper: "the operator is generic, implementation of a
controller pod is specific for a given external resource manager").

Two execution modes share these semantics (``mode=`` kwarg):

  * ``"multiplexed"`` (default) — jobs run as ``MonitorTask``s on one shared
    ``MonitorRuntime`` (core/monitor.py): monitor threads = pool size, not
    CR count.  The scalable shape for large arrays / many CRs.
  * ``"pod-per-cr"`` — the paper-faithful one-``ControllerPod``-thread-per-CR
    fallback.

Both populate ``self.pods`` with objects sharing the ControllerPod surface,
so restart / kill / resume / delete flow through identical code paths.
"""
from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from typing import Dict, Mapping, Optional, Type

from repro.core.backends import base as B
from repro.core.controller import ControllerPod
from repro.core.monitor import MonitorRuntime
from repro.core.objectstore import ObjectStore
from repro.core.registry import ResourceRegistry
from repro.core.resource import (ALL_STATES, BridgeJob, DONE, FAILED, KILLED,
                                 PENDING, RUNNING, SERVICE_KIND, SUBMITTED,
                                 TERMINAL_STATES, UNKNOWN)
from repro.core.rest import ResourceManagerDirectory
from repro.core.scheduler import LoadProbe, plan_placement
from repro.core.secrets import SecretStore
from repro.core.statestore import StateStore

# default adapter registry (image prefix -> controller implementation)
def default_adapters() -> Dict[str, Type[B.ResourceAdapter]]:
    from repro.core.backends.jaxlocal import JaxLocalAdapter
    from repro.core.backends.lsf import LSFAdapter
    from repro.core.backends.quantum import QuantumAdapter
    from repro.core.backends.ray import RayAdapter
    from repro.core.backends.slurm import SlurmAdapter

    return {a.image: a for a in
            (SlurmAdapter, LSFAdapter, QuantumAdapter, RayAdapter,
             JaxLocalAdapter)}


class BridgeOperator:
    def __init__(self, registry: ResourceRegistry, statestore: StateStore,
                 secrets: SecretStore, objectstore: ObjectStore,
                 directory: ResourceManagerDirectory,
                 adapters: Optional[Mapping[str, Type[B.ResourceAdapter]]] = None,
                 reconcile_interval: float = 0.02,
                 max_restarts: Optional[int] = None,
                 pod_min_sleep: float = 0.005,
                 mode: str = "multiplexed",
                 monitor_workers: int = 4,
                 cadence: str = "fixed"):
        if mode not in ("multiplexed", "pod-per-cr"):
            raise ValueError(f"unknown operator mode {mode!r}")
        if cadence not in ("fixed", "adaptive", "watch", "wakeup"):
            raise ValueError(f"unknown cadence mode {cadence!r}")
        self.registry = registry
        self.statestore = statestore
        self.secrets = secrets
        self.s3 = objectstore
        self.directory = directory
        self.adapters = dict(adapters or default_adapters())
        self.reconcile_interval = reconcile_interval
        self.max_restarts = max_restarts
        self.pod_min_sleep = pod_min_sleep
        self.mode = mode
        self.cadence = cadence
        self.runtime: Optional[MonitorRuntime] = (
            MonitorRuntime(workers=monitor_workers)
            if mode == "multiplexed" else None)
        self.pods: Dict[str, ControllerPod] = {}
        self._events: "queue.Queue" = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.RLock()
        # v1beta1 ttlSecondsAfterFinished: uid -> first-seen-terminal time
        self._terminal_at: Dict[str, float] = {}
        # sharded placement: queue-load prober for slice assignment (shared
        # TTL cache + concurrent probe, same machinery the scheduler uses)
        self._load_probe = LoadProbe(self._connect_adapter)

    def _connect_adapter(self, url: str, image: str,
                         secret: str) -> B.ResourceAdapter:
        token = self.secrets.mount(secret).get("token", "")
        client = self.directory.connect(url, token)
        return B.resolve_adapter(self.adapters, image)(client)

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "BridgeOperator":
        if self.runtime is not None:
            self.runtime.start()
        self._events = self.registry.watch(include_existing=True)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="bridge-operator")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)
        self.registry.unwatch(self._events)
        # snapshot under the lock: the reconcile thread (if its join timed
        # out above) may still pop entries via _finalize_delete, and
        # iterating the live dict would crash with dict-changed-size
        with self._lock:
            pods = list(self.pods.values())
        for pod in pods:
            pod.kill_pod()
        for pod in pods:
            pod.join(timeout=1.0)  # bounded: pods die at a checkpoint
        if self.runtime is not None:
            self.runtime.stop()

    # -- naming ----------------------------------------------------------------

    @staticmethod
    def cm_name(job: BridgeJob) -> str:
        return f"{job.uid}-bridge-cm"

    # -- reconcile loop -----------------------------------------------------

    def _loop(self) -> None:
        # Events are handled the moment they arrive (the blocking get wakes
        # on the first one, the inner drain batches the rest), but the sweep
        # — a FULL registry pass: status mirror, pod-exit restart, TTL GC —
        # runs at most once per reconcile_interval.  It must not be coupled
        # to event arrival: _mirror_status itself fires MODIFIED events into
        # this same queue, so sweep-per-drain self-sustains into a hot spin
        # that at 10k CRs eats the core the monitor needs.
        next_sweep = 0.0
        while not self._stop.is_set():
            now = time.time()
            if now >= next_sweep:
                self._sweep()
                next_sweep = time.time() + self.reconcile_interval
            try:
                # bounded wait so a large reconcile_interval never pins the
                # thread in get() past the stop() join budget
                event, job = self._events.get(
                    timeout=min(max(next_sweep - time.time(), 0.001), 0.1))
            except queue.Empty:
                continue
            self._handle_event(event, job)
            try:
                while True:
                    event, job = self._events.get_nowait()
                    self._handle_event(event, job)
            except queue.Empty:
                pass

    def _handle_event(self, event: str, job: BridgeJob) -> None:
        if event == "ADDED":
            self._ensure_started(job)
        elif event == "MODIFIED":
            if job.spec.kill and not job.status.terminal():
                try:
                    self.statestore.get(self.cm_name(job)).update({"kill": "true"})
                except KeyError:
                    pass
            self._reconcile_spec(job)
        elif event == "DELETED":
            self._finalize_delete(job)

    def _reconcile_spec(self, job: BridgeJob) -> None:
        """Spec-patch reconcile (elastic arrays): when metadata.generation
        moved past what the config map carries, publish the new desired state
        (array count + per-index params) and poke the pod so its next tick
        diffs desired vs. submitted indices and applies exactly the delta.
        MODIFIED events fired by status mirroring carry an unchanged
        generation and return immediately."""
        if job.deleted or job.status.terminal():
            return
        try:
            cm = self.statestore.get(self.cm_name(job))
        except KeyError:
            return  # no pod yet; _cm_payload will carry the latest spec
        if cm.get("generation") == str(job.generation):
            return
        updates = {"generation": str(job.generation)}
        if getattr(job, "kind", None) == SERVICE_KIND:
            # a BridgeService's elastic knob is spec.replicas, carried on
            # the same cm key the array reconcile machinery diffs against
            updates["array_count"] = str(job.spec.replicas)
        elif job.spec.array is not None:
            updates["array_count"] = str(job.spec.array.count)
            updates["indexed_params"] = json.dumps(
                job.spec.array.indexed_params)
        cm.update(updates)
        pod = self.pods.get(job.uid)
        if pod is not None:
            pod.poke()

    def _ensure_started(self, job: BridgeJob) -> None:
        with self._lock:
            if not self._startable(job):
                return
        # sharded placement: assign slices ONCE, at config-map creation (a
        # pod restart finds the cm and resumes the recorded plan — never
        # re-planned).  The candidate probe round is remote HTTP, so it runs
        # OUTSIDE the operator lock: admission of unrelated jobs must not
        # queue behind a slow candidate endpoint.
        plan = None
        if (job.spec.placement and job.spec.placement.candidates
                and not self.statestore.exists(self.cm_name(job))):
            if getattr(job, "kind", None) == SERVICE_KIND:
                count = job.spec.replicas
            else:
                count = job.spec.array.count if job.spec.array else 1
            plan = plan_placement(count, job.spec.placement,
                                  self._load_probe)
        with self._lock:
            if not self._startable(job):  # revalidate after the probe gap
                return
            cm = self.statestore.get_or_create(
                self.cm_name(job), self._cm_payload(job, plan))
            self.registry.update_status(job.name, job.namespace, state=PENDING)
            self._spawn_pod(job)

    def _startable(self, job: BridgeJob) -> bool:
        """Admission early-outs (caller holds the lock); may settle the CR
        (killed before any pod existed, failed dependency)."""
        if job.uid in self.pods or job.deleted or job.status.terminal():
            return False
        if job.spec.kill:
            # killed while no pod exists (e.g. dependency-gated): there is
            # no config map to carry the signal, so settle the CR directly
            self.registry.update_status(
                job.name, job.namespace, state=KILLED,
                message="killed before the controller pod was created")
            return False
        return self._dependencies_ready(job)

    def _dependencies_ready(self, job: BridgeJob) -> bool:
        """v1beta1 spec.dependencies: gate pod creation on sibling CRs.

        The job waits (PENDING) until every dependency is DONE; a FAILED or
        KILLED dependency fails the dependent without ever submitting it.
        """
        blocking = None
        for dep in job.spec.dependencies:
            d = self.registry.get(dep, job.namespace)
            if d is not None and d.status.state == DONE:
                continue
            if d is not None and d.status.state in (FAILED, KILLED):
                self.registry.update_status(
                    job.name, job.namespace, state=FAILED,
                    message=f"dependency {dep!r} ended {d.status.state}")
                return False
            blocking = (f"waiting for dependency {dep!r} "
                        f"({d.status.state if d else 'absent'})")
            break
        if blocking is None:
            return True
        if (job.status.state, job.status.message) != (PENDING, blocking):
            self.registry.update_status(job.name, job.namespace,
                                        state=PENDING, message=blocking)
        return False

    def _cm_payload(self, job: BridgeJob,
                    plan: Optional[list] = None) -> Dict[str, str]:
        """Operator 'populates the configuration map with the parameters
        required for the pod's execution' (paper §5.1).

        ``plan`` is the scheduler's slice assignment for a placed job: a
        one-slice plan collapses onto the legacy target keys (byte-for-byte
        today's shape); a multi-slice plan additionally records the
        ``slices`` key the controller fans out over, with slice 0 mirrored
        into the legacy keys for observability."""
        if getattr(job, "kind", None) == SERVICE_KIND:
            return self._service_cm_payload(job, plan)
        s = job.spec
        data = {
            "resourceURL": plan[0]["resourceURL"] if plan else s.resourceURL,
            "image": plan[0]["image"] if plan else s.image,
            "resourcesecret": (plan[0]["resourcesecret"] if plan
                               else s.resourcesecret),
            "updateinterval": str(s.updateinterval),
            "jobscript": s.jobdata.jobscript,
            "scriptlocation": s.jobdata.scriptlocation,
            "additionaldata": s.jobdata.additionaldata,
            "jobproperties": json.dumps(s.jobproperties),
            "jobparams": json.dumps(s.jobdata.jobparams),
            "unknown_after": str(s.unknown_after),
            "id": "",
            "jobStatus": PENDING,
            "kill": "true" if s.kill else "false",
            "message": "",
            "generation": str(job.generation),
        }
        # written only when non-default, so legacy config maps (and every
        # pre-cadence consumer of their exact key set) keep today's shape
        if self.cadence != "fixed":
            data["cadence"] = self.cadence
        if s.s3storage:
            data["s3endpoint"] = s.s3storage.endpoint
            data["s3secret"] = s.s3storage.s3secret
            data["s3uploadfiles"] = s.s3storage.uploadfiles
            data["s3uploadbucket"] = s.s3storage.uploadbucket
        if s.array and (s.array.count > 1 or s.array.indexed_params):
            data["array_count"] = str(s.array.count)
            data["indexed_params"] = json.dumps(s.array.indexed_params)
        if s.retry and (s.retry.limit or s.retry.backoff_seconds):
            data["retry_limit"] = str(s.retry.limit)
            data["retry_backoff"] = str(s.retry.backoff_seconds)
        # slice failover policy: the controller needs the FULL candidate set
        # (not just the plan) persisted so a re-plan after a slice loss can
        # consult candidates the initial plan skipped.  A failover-enabled
        # one-slice plan still writes ``slices`` — evacuation needs the
        # sliced machinery even before a second slice exists.
        fo = s.placement.failover if s.placement else None
        if fo is not None and fo.enabled:
            data["failover_threshold"] = str(fo.unreachable_threshold)
            data["failover_grace"] = str(fo.grace_seconds)
            data["placement_strategy"] = s.placement.strategy
            data["candidates"] = json.dumps(
                [dataclasses.asdict(c) for c in s.placement.candidates])
        if plan and (len(plan) > 1 or (fo is not None and fo.enabled)):
            data["slices"] = json.dumps(plan)
        return data

    def _service_cm_payload(self, job, plan: Optional[list] = None) -> Dict[str, str]:
        """Config-map shape for a BridgeService.

        The service reuses the elastic-array substrate: replicas ride the
        ``array_count`` key (always written — a one-replica service is still
        a service), the template supplies the per-replica job payload, and
        the ``kind`` key tells the pod driver to run the ServiceProtocol.
        ``"Serve": "true"`` is stamped into the job properties so simulated
        clusters host a long-lived serve loop instead of a batch payload.
        """
        s = job.spec
        t = s.template
        props = dict(t.jobproperties)
        props["Serve"] = "true"
        data = {
            "kind": SERVICE_KIND,
            "resourceURL": plan[0]["resourceURL"] if plan else t.resourceURL,
            "image": plan[0]["image"] if plan else t.image,
            "resourcesecret": (plan[0]["resourcesecret"] if plan
                               else t.resourcesecret),
            "updateinterval": str(s.updateinterval),
            "jobscript": t.jobdata.jobscript,
            "scriptlocation": t.jobdata.scriptlocation,
            "additionaldata": t.jobdata.additionaldata,
            "jobproperties": json.dumps(props),
            "jobparams": json.dumps(t.jobdata.jobparams),
            "unknown_after": str(s.unknown_after),
            "id": "",
            "jobStatus": PENDING,
            "kill": "true" if s.kill else "false",
            "message": "",
            "generation": str(job.generation),
            "array_count": str(s.replicas),
            "health_failure_threshold": str(s.health.failure_threshold),
            "health_startup_threshold": str(s.health.startup_failure_threshold),
        }
        if s.autoscale is not None:
            # written ONLY when spec.autoscale is set, so a plain service's
            # config map stays byte-identical to the pre-autoscale shape
            a = s.autoscale
            data["autoscale_min"] = str(a.min_replicas)
            data["autoscale_max"] = str(a.max_replicas)
            if a.target_outstanding_per_replica is not None:
                data["autoscale_target_outstanding"] = str(
                    a.target_outstanding_per_replica)
            if a.target_p99_seconds is not None:
                data["autoscale_target_p99"] = str(a.target_p99_seconds)
            data["autoscale_up_cooldown"] = str(a.scale_up_cooldown_seconds)
            data["autoscale_down_cooldown"] = str(
                a.scale_down_cooldown_seconds)
        if self.cadence != "fixed":
            data["cadence"] = self.cadence
        if t.s3storage:
            data["s3endpoint"] = t.s3storage.endpoint
            data["s3secret"] = t.s3storage.s3secret
            data["s3uploadfiles"] = t.s3storage.uploadfiles
            data["s3uploadbucket"] = t.s3storage.uploadbucket
        if plan and len(plan) > 1:
            data["slices"] = json.dumps(plan)
        return data

    def _spawn_pod(self, job: BridgeJob) -> None:
        cm = self.statestore.get(self.cm_name(job))
        if self.runtime is not None:
            pod = self.runtime.spawn(
                name=f"{job.uid}-pod", configmap=cm, secrets=self.secrets,
                objectstore=self.s3, directory=self.directory,
                adapters=self.adapters, min_sleep=self.pod_min_sleep)
            with self._lock:
                self.pods[job.uid] = pod
            return
        pod = ControllerPod(
            name=f"{job.uid}-pod", configmap=cm, secrets=self.secrets,
            objectstore=self.s3, directory=self.directory,
            adapters=self.adapters, min_sleep=self.pod_min_sleep)
        with self._lock:
            self.pods[job.uid] = pod
        pod.start()

    # -- periodic sweep: status mirroring + pod restart -------------------------

    def _sweep(self) -> None:
        jobs = self.registry.list()
        # reverse-dependency index, built ONCE per pass (the old shape —
        # registry.list() per terminal job — made every sweep O(N²)):
        # namespace -> names some live sibling still depends on
        live_deps: Dict[str, set] = {}
        for j in jobs:
            if not j.deleted and not j.status.terminal() and j.spec.dependencies:
                live_deps.setdefault(j.namespace, set()).update(
                    j.spec.dependencies)
        for job in jobs:
            if job.deleted:
                self._finalize_delete(job)
                continue
            pod = self.pods.get(job.uid)
            if pod is None:
                self._ensure_started(job)
                self._maybe_ttl_gc(job, live_deps)
                continue
            self._mirror_status(job)
            if not pod.alive():
                self._handle_pod_exit(job, pod)
            self._maybe_ttl_gc(job, live_deps)

    def _maybe_ttl_gc(self, job: BridgeJob,
                      live_deps: Mapping[str, set]) -> None:
        """v1beta1 ttlSecondsAfterFinished: auto-delete terminal CRs."""
        ttl = job.spec.ttl_seconds_after_finished
        if ttl is None or not job.status.terminal():
            return
        first_seen = self._terminal_at.setdefault(job.uid, time.time())
        if time.time() - first_seen < ttl:
            return
        # hold the GC while a live sibling still depends on this CR — deleting
        # it would leave the dependent waiting on an absent job forever
        if job.name in live_deps.get(job.namespace, ()):
            return
        self.registry.delete(job.name, job.namespace)

    def _mirror_status(self, job: BridgeJob) -> None:
        try:
            data = self.statestore.get(self.cm_name(job)).data
        except KeyError:
            return
        state = data.get("jobStatus", PENDING)
        if state not in ALL_STATES:
            state = UNKNOWN
        fields = dict(state=state, message=data.get("message", ""),
                      job_id=data.get("id", ""))
        if data.get("start_time"):
            fields["start_time"] = float(data["start_time"])
        if data.get("end_time"):
            fields["end_time"] = float(data["end_time"])
        if data.get("index_states"):
            fields["index_states"] = json.loads(data["index_states"])
        if data.get("placements"):
            fields["placements"] = json.loads(data["placements"])
        if data.get("observed_generation"):
            fields["observed_generation"] = int(data["observed_generation"])
        if data.get("kind") == SERVICE_KIND:
            fields["ready_replicas"] = int(data.get("ready_replicas", "0") or 0)
            if data.get("endpoints"):
                fields["endpoints"] = json.loads(data["endpoints"])
            if data.get("autoscale_status"):
                fields["autoscale"] = json.loads(data["autoscale_status"])
        if any(getattr(job.status, k) != v for k, v in fields.items()):
            self.registry.update_status(job.name, job.namespace, **fields)

    def _handle_pod_exit(self, job: BridgeJob, pod: ControllerPod) -> None:
        terminal = job.status.terminal()
        if pod.phase in (ControllerPod.SUCCEEDED, ControllerPod.FAILED_PHASE):
            # pod finished its protocol; nothing to do (status already mirrored)
            return
        if terminal:
            return
        # pod died out-of-band -> restart; the new pod resumes via config map
        if (self.max_restarts is not None
                and job.status.restarts >= self.max_restarts):
            self.registry.update_status(
                job.name, job.namespace, state=UNKNOWN,
                message=f"pod crash-looped ({job.status.restarts} restarts): "
                        f"{pod.error}")
            return
        self.registry.update_status(job.name, job.namespace,
                                    restarts=job.status.restarts + 1)
        self._spawn_pod(job)

    def _finalize_delete(self, job: BridgeJob) -> None:
        """CR deletion cleans up all associated resources (paper §5.1)."""
        with self._lock:
            pod = self.pods.pop(job.uid, None)
            self._terminal_at.pop(job.uid, None)
        if pod is not None:
            pod.kill_pod()
        self.statestore.delete(self.cm_name(job))
        self.registry.purge(job.name, job.namespace)

    # -- convenience (kubectl-style sync helpers) ----------------------------

    def wait_for(self, name: str, namespace: str = "default",
                 timeout: float = 30.0) -> BridgeJob:
        """Block until the job reaches a terminal state."""
        deadline = time.time() + timeout
        while time.time() < deadline:
            job = self.registry.get(name, namespace)
            if job is not None and job.status.terminal():
                return job
            time.sleep(0.01)
        raise TimeoutError(f"BridgeJob {namespace}/{name} not terminal "
                           f"after {timeout}s "
                           f"(state={job.status.state if job else '?'})")

    def kill(self, name: str, namespace: str = "default") -> None:
        """User-facing kill signal: update the CR (paper: 'A user can also
        update the CR with a kill signal')."""
        self.registry.update_spec(
            name, lambda s: dataclasses.replace(s, kill=True), namespace)
