"""Manual expert parallelism via shard_map (beyond-paper §Perf optimization).

Baseline (`routing_impl="dropping"`): GShard-style dispatch einsums under
pjit — the SPMD partitioner sees a (B,S,E,C) dispatch tensor and usually
materializes full-E intermediates per shard, inflating HLO FLOPs/bytes.

This path (`routing_impl="ep_shard_map"`): tokens are REPLICATED across the
"model" axis (standard TP), experts are SHARDED across it.  Each model shard
therefore only ever builds the dispatch/combine tensors for its E/n local
experts and runs only its local expert FFNs; one psum over "model" merges the
partial outputs (same wire cost as a Megatron MLP all-reduce).  Dispatch
memory and dispatch FLOPs drop by n_model; no all-to-all is needed because
the tokens already live everywhere in the TP group.

Mesh discovery: the step builders install the mesh via ``ep_mesh(mesh)``
around tracing; apply_moe finds it here.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.models.moe import _router, aux_load_balance_loss
from repro.sharding import dp_axes

_state = threading.local()


@contextlib.contextmanager
def ep_mesh(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _capacity(s: int, cfg) -> int:
    m = cfg.moe
    capacity = max(int(s * m.top_k * m.capacity_factor / m.n_experts), 1)
    return (capacity + 7) // 8 * 8


def _local_routing(router, x_l, cfg, e, n_model):
    """Shared per-shard routing: top-k, local expert ids, capacity slots.
    Returns (probs, gates, lidx_c, pos, keep, capacity, e_loc, midx)."""
    m = cfg.moe
    e_loc = e // n_model
    midx = jax.lax.axis_index("model")
    s = x_l.shape[1]
    capacity = _capacity(s, cfg)
    probs, gates, idx = _router({"router": router}, x_l, cfg)
    lidx = idx - midx * e_loc
    mine = (lidx >= 0) & (lidx < e_loc)
    lidx_c = jnp.clip(lidx, 0, e_loc - 1)
    onehot = jax.nn.one_hot(lidx_c, e_loc, dtype=jnp.int32)
    onehot = onehot * mine[..., None].astype(jnp.int32)       # (B,S,k,El)
    bl = x_l.shape[0]
    flat = onehot.reshape(bl, s * m.top_k, e_loc)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(bl, s, m.top_k)
    keep = (pos < capacity) & mine
    return probs, gates, idx, lidx_c, pos, keep, capacity, e_loc, midx


def moe_ep_gather(p: Dict[str, Any], x: jax.Array, cfg
                  ) -> Tuple[jax.Array, jax.Array]:
    """EP with GATHER/SCATTER dispatch (beyond-paper §Perf iteration 2).

    The one-hot dispatch of `moe_ep_shard_map` still pays two
    O(B·S·E_loc·C·d) matmuls to move tokens.  Routing is a PERMUTATION, not
    a contraction: build the slot->token index map once (integer scatter),
    then dispatch = one gather and combine = one gather — zero matmul flops
    and O(B·(E_loc·C + S·k)·d) bytes."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        raise RuntimeError("ep_gather requires ep_mesh(mesh)")
    n_model = mesh.shape["model"]
    e = cfg.moe.e_pad
    if e % n_model != 0:
        raise ValueError(f"n_experts(_padded) {e} % model={n_model}")
    dp = dp_axes(mesh)
    dpsz = 1
    for a in dp:
        dpsz *= mesh.shape[a]
    b = x.shape[0]
    x_spec = P(dp if (b % dpsz == 0 and dpsz > 1) else None, None, None)
    has_w3 = "w3" in p
    in_specs = (x_spec, P(None, None), P("model", None, None),
                P("model", None, None)) + \
        ((P("model", None, None),) if has_w3 else ())
    out_specs = (x_spec, P())

    def local(x_l, router, w1, w2, *maybe_w3):
        m = cfg.moe
        bl, s, d = x_l.shape
        probs, gates, idx_g, lidx_c, pos, keep, capacity, e_loc, _ = \
            _local_routing(router, x_l, cfg, e, n_model)
        bidx = jax.lax.broadcasted_iota(jnp.int32, (bl, s, m.top_k), 0)
        sidx = jax.lax.broadcasted_iota(jnp.int32, (bl, s, m.top_k), 1)
        pos_eff = jnp.where(keep, pos, capacity)  # dropped -> OOB (ignored)

        slot_tok = jnp.zeros((bl, e_loc, capacity), jnp.int32)
        slot_tok = slot_tok.at[bidx, lidx_c, pos_eff].set(sidx, mode="drop")
        slot_use = jnp.zeros((bl, e_loc, capacity), x_l.dtype)
        slot_use = slot_use.at[bidx, lidx_c, pos_eff].set(1.0, mode="drop")

        bidx2 = jax.lax.broadcasted_iota(jnp.int32, (bl, e_loc, capacity), 0)
        h = x_l[bidx2, slot_tok] * slot_use[..., None]     # gather dispatch
        h = h.swapaxes(0, 1).reshape(e_loc, bl * capacity, d)
        u = jnp.einsum("ecd,edf->ecf", h, w1)
        if cfg.activation == "swiglu":
            u = jax.nn.silu(u) * jnp.einsum("ecd,edf->ecf", h, maybe_w3[0])
        elif cfg.activation == "geglu":
            u = jax.nn.gelu(u) * jnp.einsum("ecd,edf->ecf", h, maybe_w3[0])
        elif cfg.activation == "relu2":
            u = jnp.square(jax.nn.relu(u))
        else:
            u = jax.nn.gelu(u)
        out_e = jnp.einsum("ecf,efd->ecd", u, w2)
        out_e = out_e.reshape(e_loc, bl, capacity, d).swapaxes(0, 1)

        pos_c = jnp.minimum(pos_eff, capacity - 1)
        y_sk = out_e[bidx, lidx_c, pos_c]                  # gather combine
        w = (gates * keep.astype(gates.dtype)).astype(x_l.dtype)
        y_partial = jnp.einsum("bsk,bskd->bsd", w, y_sk)
        y = jax.lax.psum(y_partial, "model")

        aux = aux_load_balance_loss(probs, idx_g, cfg.moe.n_experts)
        for a in dp:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    args = (x, p["router"], p["w1"], p["w2"]) + ((p["w3"],) if has_w3 else ())
    return shard_map(local, mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(*args)


def moe_ep_shard_map(p: Dict[str, Any], x: jax.Array, cfg
                     ) -> Tuple[jax.Array, jax.Array]:
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        raise RuntimeError("ep_shard_map requires ep_mesh(mesh) with a "
                           "'model' axis; use routing_impl='dropping' locally")
    n_model = mesh.shape["model"]
    e = cfg.moe.e_pad  # padded expert count (pads are never routed to)
    if e % n_model != 0:
        raise ValueError(f"n_experts(_padded) {e} not divisible by "
                         f"model={n_model}")
    dp = dp_axes(mesh)
    dpsz = 1
    for a in dp:
        dpsz *= mesh.shape[a]
    b = x.shape[0]
    x_spec = P(dp if (b % dpsz == 0 and dpsz > 1) else None, None, None)

    has_w3 = "w3" in p
    in_specs = (
        x_spec,                      # x
        P(None, None),               # router (replicated; small)
        P("model", None, None),      # w1
        P("model", None, None),      # w2
    ) + ((P("model", None, None),) if has_w3 else ())
    out_specs = (x_spec, P())

    def local(x_l, router, w1, w2, *maybe_w3):
        m = cfg.moe
        e_loc = e // n_model
        midx = jax.lax.axis_index("model")
        bl, s, d = x_l.shape
        capacity = _capacity(s, cfg)

        probs, gates, idx = _router({"router": router}, x_l, cfg)
        # local expert index; out-of-range marks "not my expert"
        lidx = idx - midx * e_loc
        mine = (lidx >= 0) & (lidx < e_loc)
        lidx_c = jnp.clip(lidx, 0, e_loc - 1)

        onehot = jax.nn.one_hot(lidx_c, e_loc, dtype=jnp.int32)
        onehot = onehot * mine[..., None].astype(jnp.int32)       # (B,S,k,El)
        flat = onehot.reshape(bl, s * m.top_k, e_loc)
        pos_in_expert = jnp.cumsum(flat, axis=1) - flat
        pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(bl, s, m.top_k)
        keep = (pos < capacity) & mine

        oh_f = onehot.astype(x_l.dtype)
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=x_l.dtype)
        kept = pos_oh * keep[..., None].astype(x_l.dtype)
        dispatch = jnp.einsum("bske,bskc->bsec", oh_f, kept)       # (B,S,El,C)
        combine = jnp.einsum("bsk,bske,bskc->bsec",
                             gates.astype(x_l.dtype), oh_f, kept)

        h = jnp.einsum("bsec,bsd->ebcd", dispatch, x_l)            # (El,B,C,d)
        h = h.reshape(e_loc, bl * capacity, d)
        u = jnp.einsum("ecd,edf->ecf", h, w1)
        if cfg.activation == "swiglu":
            u = jax.nn.silu(u) * jnp.einsum("ecd,edf->ecf", h, maybe_w3[0])
        elif cfg.activation == "geglu":
            u = jax.nn.gelu(u) * jnp.einsum("ecd,edf->ecf", h, maybe_w3[0])
        elif cfg.activation == "relu2":
            u = jnp.square(jax.nn.relu(u))
        else:
            u = jax.nn.gelu(u)
        out_e = jnp.einsum("ecf,efd->ecd", u, w2)
        out_e = out_e.reshape(e_loc, bl, capacity, d)
        y_partial = jnp.einsum("bsec,ebcd->bsd", combine, out_e)
        y = jax.lax.psum(y_partial, "model")                       # merge experts

        aux = aux_load_balance_loss(probs, idx, cfg.moe.n_experts)
        for a in dp:  # mean over data-parallel shards; model is replicated
            aux = jax.lax.pmean(aux, a)
        return y, aux

    args = (x, p["router"], p["w1"], p["w2"]) + ((p["w3"],) if has_w3 else ())
    return shard_map(local, mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(*args)
