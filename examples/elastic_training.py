"""Fault tolerance + elasticity end-to-end:

  1. a bridged training job CRASHES mid-run (injected node failure);
     resubmission with the same workdir resumes from the last checkpoint,
  2. the controller POD is killed mid-run; the operator restarts it and the
     new pod re-attaches to the running job (no resubmission),
  3. an elastic job array is resized while running (scale 4 -> 8 -> 2);
     the operator submits/cancels exactly the delta,
  4. straggler mitigation: the load-aware scheduler launches the payload
     speculatively on the two least-loaded backends and keeps the winner.

  PYTHONPATH=src python examples/elastic_training.py
"""
import json
import time

from repro.core import (ArraySpec, BridgeEnvironment, Candidate, DONE, FAILED,
                        IMAGES, KILLED, LoadAwareScheduler, RUNNING, URLS)


def main() -> None:
    with BridgeEnvironment(default_duration=0.1) as env:
        # -- 1: crash + checkpoint resume ---------------------------------
        payload = {"arch": "gemma-2b", "steps": 40, "batch": 2, "seq": 16,
                   "checkpoint_every": 10, "workdir": "ckpts:runs/elastic",
                   "lr": 1e-2, "crash_at_step": 25}
        spec = env.make_spec("jaxlocal", script=json.dumps(payload),
                             updateinterval=0.1,
                             jobproperties={"OutputFileName": "train.out"})
        env.submit("crashy", spec)
        job = env.operator.wait_for("crashy", timeout=180)
        print(f"1a. injected crash: state={job.status.state} "
              f"({job.status.message[:60]})")
        assert job.status.state == FAILED

        payload["crash_at_step"] = 0
        spec2 = env.make_spec("jaxlocal", script=json.dumps(payload),
                              updateinterval=0.1,
                              jobproperties={"OutputFileName": "train.out"})
        env.submit("resumed", spec2)
        job = env.operator.wait_for("resumed", timeout=180)
        cm = env.statestore.get(env.operator.cm_name(job))
        result = json.loads(env.clusters["jaxlocal"]
                            .jobs[cm.get("id")].outputs["train.out"])
        print(f"1b. resubmission resumed from step {result['start_step']} "
              f"(not 0) -> {job.status.state}")
        assert result["start_step"] == 20 and job.status.state == DONE

        # -- 2: pod kill, training survives ---------------------------------
        payload = {"arch": "gemma-2b", "steps": 60, "batch": 2, "seq": 16,
                   "checkpoint_every": 20, "workdir": "ckpts:runs/podkill",
                   "lr": 1e-2}
        spec3 = env.make_spec("jaxlocal", script=json.dumps(payload),
                              updateinterval=0.1,
                              jobproperties={"OutputFileName": "train.out"})
        env.submit("podkill", spec3)
        while True:
            job = env.registry.get("podkill")
            if job.status.state == RUNNING and job.status.job_id:
                break
            time.sleep(0.05)
        first_id = job.status.job_id
        env.operator.pods["default/podkill"].kill_pod()
        print("2a. controller pod killed while training runs remotely...")
        job = env.operator.wait_for("podkill", timeout=180)
        print(f"2b. state={job.status.state}, restarts={job.status.restarts}, "
              f"same remote id={job.status.job_id == first_id}")
        assert job.status.state == DONE and job.status.job_id == first_id

        # -- 3: elastic job array — resize a live ensemble -------------------
        members = env.make_spec("slurm", script="ensemble member",
                                updateinterval=0.02,
                                jobproperties={"WallSeconds": "30"},
                                array=ArraySpec(count=4))
        h = env.bridge.submit("ensemble", members)
        deadline = time.time() + 60
        while len([s for s in h.status().job_id.split(",") if s]) < 4:
            assert not h.status().terminal(), h.status().message
            assert time.time() < deadline, "ensemble fan-out timed out"
            time.sleep(0.02)
        h.scale(8)                       # grow: submits indices 4..7 only
        job = h.wait_reconciled(timeout=60)
        n_up = len(job.status.job_id.split(","))
        h.scale(2)                       # shrink: cancels indices 2..7
        job = h.wait_reconciled(timeout=60)
        n_down = len(job.status.job_id.split(","))
        cancelled = sum(1 for j in env.clusters["slurm"].jobs.values()
                        if j.state == "CANCELLED")
        print(f"3.  elastic array 4 -> {n_up} -> {n_down} "
              f"(generation={job.generation}, observed="
              f"{job.status.observed_generation}, {cancelled} cancelled)")
        assert (n_up, n_down) == (8, 2) and cancelled == 6
        assert job.status.observed_generation == job.generation
        h.cancel()

        # -- 4: speculative execution ---------------------------------------
        env.clusters["slurm"].default_duration = 8.0  # slurm = straggler
        sched = LoadAwareScheduler(
            env.bridge,
            [Candidate(URLS[k], IMAGES[k], f"{k}-secret")
             for k in ("slurm", "lsf", "ray")])
        base = env.make_spec("slurm", script="the payload",
                             updateinterval=0.05)
        t0 = time.time()
        winner = sched.submit_speculative("spec", base, n=2, timeout=60)
        print(f"4.  speculative winner: {winner.spec.resourceURL} "
              f"in {time.time()-t0:.2f}s (straggler was killed)")
        assert winner.status.state == DONE
        print("elastic training demo complete")


if __name__ == "__main__":
    main()
