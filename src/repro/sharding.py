"""Logical-axis -> mesh-axis sharding rules (t5x-style), with divisibility
fallbacks so one rule table serves every architecture.

Param logical axes used by the model defs:
  embed, heads, kv_heads, head_dim, mlp, vocab, expert, inner, layers, embed_out

Strategies:
  tp       — params sharded over "model" only, replicated over data (+pod)
  fsdp_tp  — additionally shard the "embed" axis over "data" (2-D weight
             sharding; XLA all-gathers per layer inside the scan = FSDP).
             Required for nemotron-340b-class models to fit HBM.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDef, is_paramdef, param_axes


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes: ("pod","data") on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_rules(mesh: Mesh, strategy: str = "tp") -> Dict[str, Any]:
    rules: Dict[str, Any] = {
        "layers": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "inner": "model",
        "embed_out": None,
    }
    if strategy == "fsdp_tp":
        rules["embed"] = "data"
    elif strategy != "tp":
        raise ValueError(strategy)
    return rules


def _axis_size(mesh: Mesh, axis: Any) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]], rules: Dict[str, Any],
             mesh: Mesh) -> P:
    """Resolve one param's PartitionSpec, dropping non-divisible or duplicate
    mesh-axis assignments (first dim wins)."""
    used: set = set()
    entries = []
    for dim, ax in zip(shape, axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            entries.append(None)
            continue
        axs = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        if any(a in used for a in axs) or dim % _axis_size(mesh, mesh_ax) != 0:
            entries.append(None)
            continue
        used.update(axs)
        entries.append(mesh_ax)
    return P(*entries)


def param_pspecs(defs: Any, rules: Dict[str, Any], mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda d: spec_for(d.shape, d.axes, rules, mesh), defs, is_leaf=is_paramdef
    )


def param_shardings(defs: Any, rules: Dict[str, Any], mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, spec_for(d.shape, d.axes, rules, mesh)),
        defs,
        is_leaf=is_paramdef,
    )


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspecs(batch_specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    dpsz = _axis_size(mesh, dp)
    out = {}
    for k, v in batch_specs.items():
        b = v.shape[0] if v.shape else 0
        lead = dp if (b and b % dpsz == 0) else None
        out[k] = P(lead, *([None] * (len(v.shape) - 1)))
    return out


def _auto_state_spec(shape: Sequence[int], mesh: Mesh, batch_dim: int = 0) -> P:
    """Heuristic for recurrent-state leaves: batch over dp, largest remaining
    dim over model."""
    dp = dp_axes(mesh)
    dpsz = _axis_size(mesh, dp)
    msz = mesh.shape.get("model", 1)
    entries: list = [None] * len(shape)
    if len(shape) > batch_dim and shape[batch_dim] % dpsz == 0:
        entries[batch_dim] = dp
    rest = [(d, i) for i, d in enumerate(shape) if i != batch_dim]
    for d, i in sorted(rest, reverse=True):
        if d % msz == 0 and msz > 1:
            entries[i] = "model"
            break
    return P(*entries)


def cache_pspecs(cfg, cache_spec: Any, mesh: Mesh) -> Any:
    """PartitionSpecs for a decode cache pytree (see decoding.init_cache)."""
    dp = dp_axes(mesh)
    dpsz = _axis_size(mesh, dp)
    msz = mesh.shape.get("model", 1)

    def kv_spec(s):
        # (L, B, M, Hkv, Dh).  Prefer sharding kv heads over "model"; archs
        # with fewer kv heads than the model axis (GQA kv=8 on a 16-way axis)
        # fall back to sharding head_dim — the cache then FITS at the price
        # of a scores all-reduce per layer (the collective-bound baseline the
        # §Perf sequence-sharded decode attacks).
        bt = dp if s.shape[1] % dpsz == 0 else None
        if getattr(cfg, "decode_seq_shard", False) and s.shape[2] % msz == 0:
            return P(None, bt, "model", None, None)  # flash-decode layout
        if s.shape[3] % msz == 0:
            return P(None, bt, None, "model", None)
        if s.shape[4] % msz == 0:
            return P(None, bt, None, None, "model")
        return P(None, bt, None, None, None)

    out: Dict[str, Any] = {}
    for key, val in cache_spec.items():
        if key in ("k", "v", "cross_k", "cross_v"):
            out[key] = kv_spec(val)
        elif key == "conv":  # (L,B,k-1,di)
            bt = dp if val.shape[1] % dpsz == 0 else None
            out[key] = P(None, bt, None, "model" if val.shape[3] % msz == 0 else None)
        elif key == "ssm":  # (L,B,di,n)
            bt = dp if val.shape[1] % dpsz == 0 else None
            out[key] = P(None, bt, "model" if val.shape[2] % msz == 0 else None, None)
        elif key == "pos":
            out[key] = P(None)
        elif key == "blocks":  # xlstm: list of per-layer state dicts
            out[key] = jax.tree_util.tree_map(
                lambda s: _auto_state_spec(s.shape, mesh), val
            )
        else:
            raise KeyError(key)
    return out


def to_shardings(pspec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
